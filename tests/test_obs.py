"""Observability layer (DESIGN.md §16): registry semantics (counters /
gauges / histograms, disabled no-op, thread-safety), span tracer + Chrome
trace export, the scheduler percentile hardening, and the acceptance bars —
a scripted serve run produces a correctly-ordered span tree with every
lifecycle phase, and tracing changes NOTHING: greedy outputs stay
bit-identical and ``host_syncs_per_step`` stays 0.0.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.obs.metrics import (DEFAULT_BUCKETS, NULL_REGISTRY,
                               MetricsRegistry)
from repro.obs.trace import NULL_TRACER, PHASES, TID_ENGINE, Tracer
from repro.serve.scheduler import ServeRequest, SlotScheduler, percentile

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_semantics():
    reg = MetricsRegistry()
    c = reg.counter("c", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="decrease"):
        c.inc(-1)
    assert reg.counter("c") is c  # get-or-create

def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("g")
    g.set(7)
    g.set(3)
    assert g.value == 3.0


def test_histogram_buckets_and_moments():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 10.0))
    for v in (0.5, 0.9, 5.0, 100.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["sum"] == pytest.approx(106.4)
    assert snap["buckets"] == {"1.0": 2, "10.0": 1, "+inf": 1}
    assert h.mean == pytest.approx(106.4 / 4)


def test_histogram_bounds_validated():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="sorted"):
        reg.histogram("bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError, match="sorted"):
        reg.histogram("dup", buckets=(1.0, 1.0))
    assert len(DEFAULT_BUCKETS) == len(set(DEFAULT_BUCKETS))


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("x")


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(10)
    g.set(10)
    h.observe(10)
    assert c.value == 0 and g.value == 0 and h.count == 0
    reg.enable()
    c.inc(1)
    assert c.value == 1
    reg.disable()
    c.inc(1)
    assert c.value == 1
    assert NULL_REGISTRY.enabled is False


def test_thread_safety_smoke():
    reg = MetricsRegistry()
    c = reg.counter("c")
    h = reg.histogram("h")

    def work():
        for _ in range(5000):
            c.inc()
            h.observe(0.001)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8 * 5000
    assert h.count == 8 * 5000


def test_dump_text_and_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("sched.admitted").inc(3)
    reg.histogram("lat", buckets=(1.0,)).observe(0.5)
    text = reg.dump_text()
    assert "sched.admitted 3" in text
    assert 'lat_bucket{le="1.0"} 1' in text and "lat_count 1" in text
    p = tmp_path / "metrics.json"
    reg.dump_json(str(p))
    data = json.loads(p.read_text())
    assert data["metrics"]["sched.admitted"] == 3.0
    assert data["metrics"]["lat"]["count"] == 1


# ---------------------------------------------------------------------------
# percentile hardening (scheduler satellite)
# ---------------------------------------------------------------------------


def test_percentile_empty_is_nan_not_crash():
    assert math.isnan(percentile([], 50))
    assert math.isnan(percentile([], 99))


def test_percentile_single_sample():
    for q in (0, 50, 99, 100):
        assert percentile([0.25], q) == 0.25


def test_percentile_interpolates():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 50) == pytest.approx(np.percentile(xs, 50))
    assert percentile(xs, 99) == pytest.approx(np.percentile(xs, 99))


def test_fresh_scheduler_stats_defined():
    s = SlotScheduler(2).stats()
    assert math.isnan(s["latency_p50_s"]) and math.isnan(s["first_token_p99_s"])
    for k in ("queue_depth", "submitted_total", "admitted_total",
              "retired_total", "expired_total"):
        assert s[k] == 0


def test_scheduler_registry_totals():
    reg = MetricsRegistry()
    sched = SlotScheduler(2, registry=reg)
    sched.submit(ServeRequest(rid=0, prompt=np.ones(3, np.int32), submit_t=0.0))
    sched.submit(ServeRequest(rid=1, prompt=np.ones(3, np.int32), submit_t=0.0,
                              deadline_s=0.5))
    admitted = sched.admit(now=1.0)  # rid 0 admitted; rid 1 expired in queue
    assert [r.rid for r, _ in admitted] == [0]
    sched.retire(admitted[0][1], now=2.0)
    s = sched.stats()
    assert s["submitted_total"] == 2 and s["admitted_total"] == 1
    assert s["retired_total"] == 1 and s["expired_total"] == 1
    assert s["queue_depth"] == 0
    assert reg.counter("sched.expired").value == 1


# ---------------------------------------------------------------------------
# tracer + Chrome export
# ---------------------------------------------------------------------------


def test_tracer_records_and_disabled_noop():
    tr = Tracer()
    tr.complete("prefill", ts=10.0, dur=0.5, tid=1, args={"rid": 0})
    tr.instant("enqueue", ts=9.0)
    with tr.span("warmup"):
        pass
    assert [e.name for e in tr.events] == ["prefill", "enqueue", "warmup"]
    off = Tracer(enabled=False)
    off.complete("x", ts=0, dur=1)
    off.instant("y")
    with off.span("z"):
        pass
    assert off.events == []
    assert NULL_TRACER.enabled is False


def test_chrome_export_sorted_rebased_microseconds():
    tr = Tracer()
    tr.set_track_name(TID_ENGINE, "engine")
    tr.complete("b", ts=100.002, dur=0.001)
    tr.instant("a", ts=100.000)
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
    data = [e for e in evs if e["ph"] != "M"]
    assert [e["name"] for e in data] == ["a", "b"]  # sorted by ts
    assert data[0]["ts"] == 0.0                      # rebased
    assert data[1]["ts"] == pytest.approx(2000.0, abs=1.0)   # us
    assert data[1]["dur"] == pytest.approx(1000.0)
    assert data[0]["s"] == "t"                       # instant scope
    ts = [e["ts"] for e in data]
    assert ts == sorted(ts)


def test_tracer_write_loadable(tmp_path):
    tr = Tracer()
    tr.instant("enqueue", ts=1.0, args={"rid": 0})
    p = tmp_path / "trace.json"
    n = tr.write(str(p))
    assert n == 1
    doc = json.loads(p.read_text())
    assert isinstance(doc["traceEvents"], list)


def test_negative_duration_clamped():
    tr = Tracer()
    tr.complete("x", ts=5.0, dur=-1.0)
    assert tr.events[0].dur == 0.0


# ---------------------------------------------------------------------------
# serve-run span tree + tracing-changes-nothing (needs jax)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.configs import get_smoke_config          # noqa: E402
from repro.models.api import get_model              # noqa: E402
from repro.serve.engine import ServeEngine          # noqa: E402

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        model = get_model(get_smoke_config(arch))
        _MODELS[arch] = (model, model.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def _template(n=40, lo=1, hi=50):
    return (np.arange(1, n + 1, dtype=np.int32) * 7) % (hi - lo) + lo


def _engine(arch="qwen2_1_5b", *, tracer=None, metrics=None, slots=2,
            block=8, pool_blocks=24, prefix=True):
    model, params = _model(arch)
    return ServeEngine(model, params, capacity=64, slots=slots,
                       pool_tokens=pool_blocks * block, block_size=block,
                       prefix_cache=prefix, tracer=tracer, metrics=metrics)


def _drive(eng, prompts, max_new=6, deadlines=None):
    rids = [eng.submit(p, max_new_tokens=max_new,
                       deadline_s=None if deadlines is None else deadlines[i])
            for i, p in enumerate(prompts)]
    while eng.step():
        pass
    done = {r.rid: np.asarray(r.tokens, np.int32)
            for r in eng.sched.finished + eng.sched.dropped}
    return [done[r] for r in rids]


def test_serve_span_tree_ordering_and_phases():
    tr = Tracer()
    reg = MetricsRegistry()
    eng = _engine(tracer=tr, metrics=reg, slots=1)
    t = _template(40)
    tail = _template(4, lo=50, hi=60)
    # rid0 cold donor; rid1 identical (full coverage -> COW); rid2 shares
    # the 40-token template then diverges (partial hit)
    _drive(eng, [t, t.copy(), np.concatenate([t, tail])])

    by = {}
    for e in tr.events:
        by.setdefault(e.name, []).append(e)
    for ph in PHASES:
        assert by.get(ph), f"no {ph!r} span recorded"
    assert by.get("prefix_hit") and by.get("cow_copy")

    # per-request lifecycle ordering: enqueue <= admit <= prefill <= retire
    def rid_ts(name, rid):
        for e in by[name]:
            a = e.args or {}
            if a.get("rid") == rid or rid in a.get("rids", []):
                return e.ts
        raise AssertionError(f"no {name} event for rid {rid}")

    for rid in range(3):
        tq, ta = rid_ts("enqueue", rid), rid_ts("admit", rid)
        tp, tr_ = rid_ts("prefill", rid), rid_ts("retire", rid)
        assert tq <= ta <= tp <= tr_

    # decode aggregates cover every step, flushed at idle
    steps = sum(e.args["steps"] for e in by["decode"])
    assert steps == eng.stats["decode_steps"] > 0

    # registry saw the same lifecycle the scheduler reports
    snap = reg.snapshot()
    assert snap["sched.admitted"] == 3 and snap["sched.retired"] == 3
    assert snap["engine.cow_copies"] == eng.stats["cow_copies"] >= 1
    assert snap["pool.prefix_hits"] >= 1
    assert snap["engine.tokens_out"] == eng.stats["tokens_generated"]

    # export is valid, monotonic, and carries every phase
    doc = tr.to_chrome()
    ts = [e["ts"] for e in doc["traceEvents"] if "ts" in e]
    assert ts == sorted(ts) and min(ts) == 0.0


def test_expire_instant_on_deadline_drop():
    tr = Tracer()
    eng = _engine(tracer=tr, slots=1, prefix=False)
    t = _template(24)
    outs = _drive(eng, [t, t, t], max_new=8,
                  deadlines=[None, -1.0, None])  # rid1 expires while queued
    assert outs[1].size == 0
    expires = [e for e in tr.events if e.name == "expire"]
    assert len(expires) == 1 and expires[0].args["rid"] == 1
    assert eng.stats["expired_total"] == 1


def test_tracing_changes_nothing_bit_identical_greedy():
    prompts = [_template(40), _template(40),
               np.concatenate([_template(40), _template(3, lo=50, hi=60)])]
    base = _engine()                                   # default: NULL tracer
    plain = _drive(base, [p.copy() for p in prompts])
    tr = Tracer()
    traced_eng = _engine(tracer=tr, metrics=MetricsRegistry())
    traced = _drive(traced_eng, [p.copy() for p in prompts])
    assert len(plain) == len(traced) == 3
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a, b)
    # the invariant tracing must not break: zero per-step host syncs, and
    # the tracer actually recorded the run
    assert traced_eng.stats["host_syncs_per_step"] == 0.0
    assert len(tr.events) > 0
    base.check_invariants()
    traced_eng.check_invariants()
