"""Causal/streaming FLARE (DESIGN.md §3.1): equivalences + stability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flare_stream import (
    flare_causal,
    flare_causal_ref,
    stream_append,
    stream_chunk,
    stream_init,
)

KEY = jax.random.PRNGKey(1)


def _qkv(b=2, h=3, n=32, m=8, d=8, scale=0.5):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (h, m, d)) * scale
    k = jax.random.normal(ks[1], (b, h, n, d)) * scale
    v = jax.random.normal(ks[2], (b, h, n, d))
    return q, k, v


def test_chunked_equals_ref():
    q, k, v = _qkv()
    y = flare_causal(q, k, v, chunk_size=8)
    y_ref = flare_causal_ref(q, k, v)
    np.testing.assert_allclose(y, y_ref, atol=1e-5)


def test_chunk_size_invariance():
    q, k, v = _qkv(n=32)
    y8 = flare_causal(q, k, v, chunk_size=8)
    y16 = flare_causal(q, k, v, chunk_size=16)
    y32 = flare_causal(q, k, v, chunk_size=32)
    np.testing.assert_allclose(y8, y16, atol=1e-5)
    np.testing.assert_allclose(y8, y32, atol=1e-5)


def test_append_loop_equals_chunked():
    """Token-by-token serving path == chunked training path."""
    q, k, v = _qkv(n=16)
    b, h, n, d = k.shape
    m = q.shape[1]
    state = stream_init(b, h, m, d)
    outs = []
    for t in range(n):
        state, y = stream_append(state, q, k[:, :, t], v[:, :, t])
        outs.append(y)
    y_loop = jnp.stack(outs, axis=2)
    y_chunk = flare_causal(q, k, v, chunk_size=8)
    np.testing.assert_allclose(y_loop, y_chunk, atol=1e-5)


def test_prefix_causality_exact_path():
    """Output at t must not depend on tokens > t — even under adversarial
    future values (the exact path's guarantee)."""
    q, k, v = _qkv(n=16)
    y_full = flare_causal(q, k, v, chunk_size=8, impl="exact")
    k2 = k.at[:, :, 12:].set(99.0)
    v2 = v.at[:, :, 12:].set(-99.0)
    y_pre = flare_causal(q, k2, v2, chunk_size=8, impl="exact")
    np.testing.assert_allclose(y_full[:, :, :12], y_pre[:, :, :12], atol=1e-5)


def test_prefix_causality_factored_path():
    """The factored path is causal within its bounded-score contract
    (future scores within ~85 nats of the running max)."""
    q, k, v = _qkv(n=16)
    y_full = flare_causal(q, k, v, chunk_size=8, impl="factored")
    k2 = k.at[:, :, 12:].set(4.0)   # large-but-realistic future change
    v2 = v.at[:, :, 12:].set(-4.0)
    y_pre = flare_causal(q, k2, v2, chunk_size=8, impl="factored")
    np.testing.assert_allclose(y_full[:, :, :12], y_pre[:, :, :12], atol=1e-5)


def test_factored_equals_exact_realistic():
    q, k, v = _qkv(n=32, scale=1.5)
    y_f = flare_causal(q, k, v, chunk_size=8, impl="factored")
    y_e = flare_causal(q, k, v, chunk_size=8, impl="exact")
    np.testing.assert_allclose(y_f, y_e, atol=1e-5)


def test_state_carries_across_chunks():
    q, k, v = _qkv(n=32)
    b, h, n, d = k.shape
    m = q.shape[1]
    s1 = stream_init(b, h, m, d)
    s1, y1 = stream_chunk(s1, q, k[:, :, :16], v[:, :, :16])
    s1, y2 = stream_chunk(s1, q, k[:, :, 16:], v[:, :, 16:])
    y_two = jnp.concatenate([y1, y2], axis=2)
    y_one = flare_causal(q, k, v, chunk_size=32)
    np.testing.assert_allclose(y_two, y_one, atol=1e-5)


def test_500k_style_stability():
    """Long-stream numerical stability: many appends with large scores."""
    q, k, v = _qkv(n=256, scale=4.0)
    y = flare_causal(q, k, v, chunk_size=64)
    assert bool(jnp.isfinite(y).all())


def test_state_size_constant():
    """The decode state is O(M*D) per head — independent of tokens seen."""
    q, k, v = _qkv(n=64)
    b, h, n, d = k.shape
    m = q.shape[1]
    state = stream_init(b, h, m, d)
    sizes0 = [x.size for x in state]
    state, _ = stream_chunk(state, q, k, v)
    assert [x.size for x in state] == sizes0
