"""flarecheck (DESIGN.md §14): per-rule positive/negative source fixtures,
suppression + baseline mechanics, the allocator sanitizer's detectors, and
the acceptance bar — seeding a host sync into the REAL engine source or
reordering the REAL attention staging must trip the right rule at the
right line, while the repo as committed lints clean.

Pure-host module (no jax import needed by the linter itself) — everything
here runs in milliseconds.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import (all_rules, apply_baseline, lint_paths,
                                 lint_source, load_baseline, write_baseline)
from repro.serve.pool import BlockAllocator

REPO = Path(__file__).resolve().parent.parent

# synthetic paths that land in each checker's scope
ENGINE = "src/repro/serve/engine.py"
ATTN = "src/repro/models/attention.py"
KERNEL = "src/repro/kernels/synthetic.py"
POLICY = "src/repro/core/policy.py"


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# host-sync (HS*)
# ---------------------------------------------------------------------------


def test_hs001_item_in_decode_loop():
    src = """
class ServeEngine:
    def step(self):
        toks_dev = self._decode_pool(self.pool)
        t = toks_dev[0].item()
        return t
"""
    fs = lint_source(src, ENGINE)
    assert rules_of(fs) == ["HS001"] and fs[0].line == 5


def test_hs002_float_on_device_value():
    src = """
class ServeEngine:
    def _decode_pool(self, toks):
        logits = self._decode_step(self.params, toks)
        return float(logits[0])
"""
    assert rules_of(lint_source(src, ENGINE)) == ["HS002"]


def test_hs003_asarray_pull_and_host_result_untainted():
    src = """
class ServeEngine:
    def step(self):
        toks_dev = self._decode_pool(self.pool)
        toks = np.asarray(toks_dev)
        n = int(toks[0])
        return n
"""
    # the pull is flagged once; int() on the (host) result is NOT
    assert rules_of(lint_source(src, ENGINE)) == ["HS003"]


def test_hs004_block_until_ready_placement():
    src = """
def run(x):
    jax.block_until_ready(x)

def warmup_all(x):
    jax.block_until_ready(x)

def bench_decode(x):
    jax.block_until_ready(x)
"""
    fs = lint_source(src, ENGINE)
    assert rules_of(fs) == ["HS004"] and fs[0].line == 3


def test_hs_cold_path_not_flagged():
    src = """
class ServeEngine:
    def submit(self, prompt):
        toks = np.asarray(prompt)
        return toks.tolist()
"""
    assert lint_source(src, ENGINE) == []


# ---------------------------------------------------------------------------
# dtype-staging (DS*)
# ---------------------------------------------------------------------------

CANONICAL = """
def attn(q, k, v, scale, bias):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = s + bias
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
"""

REORDERED = """
def attn(q, k, v, scale, bias):
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s + bias
    w = jax.nn.softmax(s, axis=-1) * scale
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
"""


def test_ds_canonical_clean():
    assert lint_source(CANONICAL, ATTN) == []


def test_ds001_scale_after_softmax():
    fs = lint_source(REORDERED, ATTN)
    assert rules_of(fs) == ["DS001"] and fs[0].line == 6


def test_ds002_mask_after_softmax():
    src = """
def attn(q, k, v, scale, mask):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    w = jax.nn.softmax(s)
    w = jnp.where(mask, w, -jnp.inf)
    return w
"""
    assert rules_of(lint_source(src, ATTN)) == ["DS002"]


def test_ds003_unstaged_scale():
    src = """
def attn(q, k, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    return jax.nn.softmax(s)
"""
    assert rules_of(lint_source(src, ATTN)) == ["DS003"]


def test_ds_preferred_element_type_counts_as_staged():
    src = """
def kernel(q_ref, k_ref, scale):
    s = jax.lax.dot_general(q_ref[...], k_ref[...], dims,
                            preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(ok, s, NEG_INF)
    return jax.nn.softmax(s)
"""
    assert lint_source(src, KERNEL) == []


def test_ds_flash_correction_factor_not_flagged():
    # exp(m_prev - m_new) rescaling in flash-style kernels must not read
    # as softmax-after-scale
    src = """
def kernel(q, k, v, scale, m_prev, acc):
    s = jax.lax.dot_general(q, k, dims,
                            preferred_element_type=jnp.float32) * scale
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    acc = acc * alpha + jax.lax.dot_general(p, v, dims2)
    return acc
"""
    assert lint_source(src, KERNEL) == []


# ---------------------------------------------------------------------------
# retrace-hazard (RT*)
# ---------------------------------------------------------------------------


def test_rt001_jit_in_loop():
    src = """
def build(fns):
    out = []
    for f in fns:
        out.append(jax.jit(f))
    return out
"""
    assert rules_of(lint_source(src, POLICY)) == ["RT001"]


def test_rt002_array_static_arg():
    src = """
def make(fn):
    return jax.jit(fn, static_argnames=("params",))
"""
    assert rules_of(lint_source(src, POLICY)) == ["RT002"]


def test_rt002_scalar_static_arg_ok():
    src = """
def make(fn):
    return jax.jit(fn, static_argnames=("bucket", "lanes"))
"""
    assert lint_source(src, POLICY) == []


def test_rt003_set_iteration():
    src = """
def leaves(names):
    out = {}
    for k in set(names):
        out[k] = 1
    return out
"""
    assert rules_of(lint_source(src, POLICY)) == ["RT003"]


def test_rt004_python_branch_on_traced():
    src = """
def step(x):
    if jnp.any(x > 0):
        return x
    return -x
"""
    assert rules_of(lint_source(src, ENGINE)) == ["RT004"]


def test_rt_host_control_flow_ok():
    src = """
def admit(self, now):
    while self.sched.waiting:
        if self.paged:
            self._stake()
"""
    assert lint_source(src, ENGINE) == []


def test_rt005_mesh_built_inside_jitted_shard_map():
    src = """
@jax.jit
def step(pool, pt):
    mesh = Mesh(jax.devices(), ("data",))
    return shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs)(pool, pt)
"""
    fs = lint_source(src, ENGINE)
    assert rules_of(fs) == ["RT005"] and fs[0].line == 5


def test_rt005_partial_jit_with_make_mesh():
    src = """
@functools.partial(jax.jit, static_argnames=("n",))
def run(x, n):
    mesh = make_mesh((n,), ("data",))
    return lax.psum(x, "data")
"""
    path = "src/repro/backends/packed_shard.py"
    assert rules_of(lint_source(src, path)) == ["RT005"]


def test_rt005_mesh_from_build_time_ok():
    # the engine idiom: mesh built at __init__, shard_map closes over it in a
    # NON-jitted builder — clean
    src = """
def _make_decode_step_sharded(self):
    mesh = self.mesh
    return shard_map(self._body, mesh=mesh, in_specs=specs, out_specs=specs)
"""
    assert lint_source(src, ENGINE) == []


def test_rt005_jitted_collective_without_mesh_ctor_ok():
    src = """
@jax.jit
def step(x):
    return lax.psum(x, "data")
"""
    assert lint_source(src, ENGINE) == []


# ---------------------------------------------------------------------------
# obs-boundary (OB*)
# ---------------------------------------------------------------------------


def test_ob001_clock_in_jitted_fn():
    src = """
@jax.jit
def fwd(params, batch):
    t0 = time.perf_counter()
    return loss(params, batch), t0
"""
    fs = lint_source(src, POLICY)
    assert rules_of(fs) == ["OB001"] and fs[0].line == 4


def test_ob001_monotonic_in_partial_jit():
    src = """
@functools.partial(jax.jit, static_argnames=("n",))
def run(x, n):
    dt = time.monotonic()
    return x * dt
"""
    assert rules_of(lint_source(src, POLICY)) == ["OB001"]


def test_ob001_metrics_inc_in_kernel():
    src = """
def flare_kernel(q_ref, k_ref, o_ref):
    _M_LAUNCHES.inc()
    o_ref[...] = q_ref[...] + k_ref[...]
"""
    fs = lint_source(src, KERNEL)
    assert rules_of(fs) == ["OB001"] and "counts traces" in fs[0].message


def test_ob001_registry_call_in_hot_scope():
    src = """
class ServeEngine:
    def _decode_pool(self, toks):
        self.metrics.counter("steps", "").inc()
        return self._decode_step(self.params, toks)
"""
    # both the registry-rooted call and the .inc() on its result are the
    # same boundary violation — one finding per call node
    fs = lint_source(src, ENGINE)
    assert rules_of(fs) == ["OB001", "OB001"]


def test_ob001_observe_inside_nested_traced_closure():
    src = """
class ServeEngine:
    def _make_decode_step(self):
        def _fused(params, toks, pool, key):
            self._m_step_s.observe(1.0)
            return self.model.decode_step(params, toks, pool)
        return _fused
"""
    # _make_decode_step matches the decode hot scope; the nested closure is
    # covered once (no duplicate findings for the nested def)
    assert rules_of(lint_source(src, ENGINE)) == ["OB001"]


def test_ob001_time_time_and_helpers_clean():
    # the sanctioned pattern: time.time stamps in the hot wrapper, metric
    # mutation delegated to a non-hot-named helper
    src = """
class ServeEngine:
    def step(self):
        t0 = time.time()
        self._decode()
        now = time.time()
        self._note_step(t0, now, 1)
        return True

    def _note_step(self, t0, now, active):
        self._m_step_s.observe(now - t0)
"""
    assert lint_source(src, ENGINE) == []


def test_ob001_cold_scope_clean():
    # clocks + metrics anywhere outside traced/hot scopes are fine
    src = """
def measure(runner):
    t0 = time.perf_counter()
    runner()
    _M_MEASURED.inc()
    return time.perf_counter() - t0
"""
    assert lint_source(src, POLICY) == []


def test_ob001_suppressible():
    src = """
@jax.jit
def fwd(params):
    # flarecheck: disable=OB001 -- trace-time stamp, deliberate
    t0 = time.perf_counter()
    return params, t0
"""
    assert lint_source(src, POLICY) == []


def test_ob001_real_engine_hot_scopes_clean_and_seeded_caught():
    src = (REPO / "src/repro/serve/engine.py").read_text()
    assert [f for f in lint_source(src, ENGINE) if f.rule == "OB001"] == []
    # seeding a counter inc into the REAL fused decode body is caught
    anchor = "self._decode_compiles += 1  # trace-time only"
    assert anchor in src
    seeded = src.replace(anchor, anchor + "\n                _M.inc()", 1)
    fs = [f for f in lint_source(seeded, ENGINE) if f.rule == "OB001"]
    assert len(fs) == 1 and "_M.inc" in fs[0].snippet


# ---------------------------------------------------------------------------
# pallas-contract (PC*)
# ---------------------------------------------------------------------------


def test_pc001_unguarded_floordiv_grid():
    src = """
def launch(x):
    m = x.shape[0]
    return pl.pallas_call(kern, grid=(m // 128,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)))(x)
"""
    assert rules_of(lint_source(src, KERNEL)) == ["PC001"]


def test_pc001_mod_guard_accepted():
    src = """
def launch(x, block_m):
    m = x.shape[0]
    if m % block_m:
        raise ValueError("needs padding")
    return pl.pallas_call(kern, grid=(m // block_m,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)))(x)
"""
    assert lint_source(src, KERNEL) == []


def test_pc002_index_map_reads_operand():
    src = """
def launch(x, table):
    return pl.pallas_call(kern, grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 128), lambda i, j: (table[i], 0))],
        out_specs=pl.BlockSpec((1, 128), lambda i, j: (i, 0)))(x, table)
"""
    fs = lint_source(src, KERNEL)
    assert rules_of(fs) == ["PC002"] and "table" in fs[0].message


def test_pc002_scalar_prefetch_param_legal():
    src = """
def launch(pt, lengths, x):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(4, 8),
        in_specs=[pl.BlockSpec((1, 128), lambda b, p, pt, ln: (pt[b, p], 0))],
        out_specs=pl.BlockSpec((1, 128), lambda b, p, pt, ln: (b, 0)))
    return pl.pallas_call(kern, grid_spec=grid_spec)(pt, lengths, x)
"""
    assert lint_source(src, KERNEL) == []


def test_pc003_vmem_budget():
    src = """
def launch(x):
    block = 4096
    return pl.pallas_call(kern, grid=(4,),
        in_specs=[pl.BlockSpec((block, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, block), lambda i: (i, 0)))(x)
"""
    # 2 * 4096*4096*4 B = 128 MiB > 16 MiB default
    fs = lint_source(src, KERNEL)
    assert rules_of(fs) == ["PC003"]
    assert lint_source(src, KERNEL, vmem_budget=256 * 2 ** 20) == []


def test_pc004_index_map_arity():
    src = """
def launch(x):
    return pl.pallas_call(kern, grid=(4, 8),
        in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 128), lambda i, j: (i, 0)))(x)
"""
    assert rules_of(lint_source(src, KERNEL)) == ["PC004"]


# ---------------------------------------------------------------------------
# suppressions + baseline
# ---------------------------------------------------------------------------

SEEDED = """
class ServeEngine:
    def step(self):
        toks_dev = self._decode_pool(self.pool)
        t = toks_dev[0].item()
        return t
"""


def test_suppression_with_justification_silences():
    src = SEEDED.replace(
        "t = toks_dev[0].item()",
        "t = toks_dev[0].item()  # flarecheck: disable=HS001 -- probe")
    assert lint_source(src, ENGINE) == []


def test_suppression_line_above():
    src = SEEDED.replace(
        "        t = toks_dev[0].item()",
        "        # flarecheck: disable=HS001 -- probe\n"
        "        t = toks_dev[0].item()")
    assert lint_source(src, ENGINE) == []


def test_bare_suppression_is_its_own_finding():
    src = SEEDED.replace(
        "t = toks_dev[0].item()",
        "t = toks_dev[0].item()  # flarecheck: disable=HS001")
    assert rules_of(lint_source(src, ENGINE)) == ["SUP001"]


def test_wrong_rule_suppression_does_not_silence():
    src = SEEDED.replace(
        "t = toks_dev[0].item()",
        "t = toks_dev[0].item()  # flarecheck: disable=DS001 -- wrong id")
    assert rules_of(lint_source(src, ENGINE)) == ["HS001"]


def test_baseline_roundtrip(tmp_path):
    fs = lint_source(SEEDED, ENGINE)
    assert len(fs) == 1
    bp = tmp_path / "base.json"
    write_baseline(str(bp), fs)
    base = load_baseline(str(bp))
    assert apply_baseline(fs, base) == []          # known finding absorbed
    assert apply_baseline(fs + fs, base) == fs     # second occurrence is NEW
    assert json.loads(bp.read_text())["version"] == 1


def test_baseline_fingerprint_survives_line_moves(tmp_path):
    bp = tmp_path / "base.json"
    write_baseline(str(bp), lint_source(SEEDED, ENGINE))
    moved = "\n\n\n" + SEEDED  # same code, three lines lower
    assert apply_baseline(lint_source(moved, ENGINE),
                          load_baseline(str(bp))) == []


# ---------------------------------------------------------------------------
# acceptance: the real repo, clean and seeded
# ---------------------------------------------------------------------------


def test_repo_lints_clean_against_baseline():
    findings = lint_paths([str(REPO / "src")])
    base = load_baseline(str(REPO / ".flarecheck.json"))
    assert apply_baseline(findings, base) == []


def test_seeded_host_sync_in_real_engine_caught():
    src = (REPO / "src/repro/serve/engine.py").read_text()
    anchor = "toks = np.asarray(toks_dev)"
    assert anchor in src
    seeded = src.replace(anchor, anchor + "\n            _ = toks_dev.item()")
    fs = [f for f in lint_source(seeded, ENGINE) if f.rule == "HS001"]
    assert len(fs) == 1
    assert fs[0].line == seeded.splitlines().index(
        "            _ = toks_dev.item()") + 1


def test_real_attention_staging_is_canonical():
    src = (REPO / "src/repro/models/attention.py").read_text()
    assert lint_source(src, ATTN) == []
    # ...and inverting the real file's scale placement is caught: multiply
    # the softmax output by scale instead of the staged scores
    bad = src.replace(
        "w = jax.nn.softmax(scores, axis=-1)",
        "w = jax.nn.softmax(scores, axis=-1) * scale", 1)
    assert bad != src
    assert "DS001" in rules_of(lint_source(bad, ATTN))


def test_cli_list_rules_and_gate(tmp_path):
    env_src = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--list-rules"],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0 and out.stdout.strip()
    assert any(line.startswith("HS001") for line in out.stdout.splitlines())
    # a seeded violation makes the gate exit non-zero with rule id + file:line
    bad = tmp_path / "engine.py"
    bad_dir = tmp_path / "serve"
    bad_dir.mkdir()
    (bad_dir / "engine.py").write_text(SEEDED)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(tmp_path)],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"})
    assert out.returncode == 1
    assert "HS001" in out.stdout and "engine.py:5" in out.stdout


# ---------------------------------------------------------------------------
# allocator sanitizer
# ---------------------------------------------------------------------------


def test_sanitizer_clean_allocator_passes():
    a = BlockAllocator(6, 8)
    lease = a.reserve(3)
    a.map(lease, 2)
    a.check_invariants()
    a.check_invariants(external_refs={0: 1, 1: 1})


def test_sanitizer_detects_free_mapped_overlap():
    a = BlockAllocator(4, 8)
    lease = a.reserve(1)
    (b,) = a.map(lease, 1)
    a._free.insert(0, b)  # corrupt: mapped block re-enters the free list
    with pytest.raises(RuntimeError, match="free and mapped"):
        a.check_invariants()


def test_sanitizer_detects_refcount_leak():
    a = BlockAllocator(4, 8)
    lease = a.reserve(1)
    a.map(lease, 1)
    with pytest.raises(RuntimeError, match="not accounted"):
        a.check_invariants(external_refs={})  # nobody admits to the ref


def test_sanitizer_detects_hash_index_asymmetry():
    a = BlockAllocator(4, 8)
    lease = a.reserve(2)
    b0, b1 = a.map(lease, 2)
    a.register(b0, b"h" * 16)
    a._by_hash[b"h" * 16] = b1  # corrupt: index points at the wrong block
    with pytest.raises(RuntimeError, match="asymmetry"):
        a.check_invariants()


def test_sanitizer_detects_zombie_refcount():
    a = BlockAllocator(4, 8)
    lease = a.reserve(1)
    (b,) = a.map(lease, 1)
    a._ref[b] = 0  # corrupt: mapped block with no references
    with pytest.raises(RuntimeError, match="refcount"):
        a.check_invariants()


def test_rule_catalog_nonempty_and_unique():
    rules = all_rules()
    ids = [r.id for r in rules]
    assert len(ids) == len(set(ids)) and len(ids) >= 14
    for prefix in ("HS", "DS", "RT", "PC", "OB", "SUP"):
        assert any(i.startswith(prefix) for i in ids)
