"""Plan-first MixerPolicy API (DESIGN.md §13): the policy stack, build-time
resolution, hashability (jit-static), legacy-alias deprecation, and the
requires_grad safety contract (a training policy can never resolve onto a
forward-only kernel, bidirectional or causal).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.dispatch import MixerPlan, MixerShape
from repro.core.flare import flare_mixer
from repro.core.policy import (
    MixerPolicy,
    current_policy,
    ensure_plan,
    mixer_policy,
    resolve_policy,
    run_plan,
)

KEY = jax.random.PRNGKey(0)
SHAPE = MixerShape(batch=2, heads=2, tokens=64, latents=8, head_dim=16)


def _qkv(h=2, m=8, n=64, d=16, b=2):
    kq, kk, kv = jax.random.split(KEY, 3)
    return (jax.random.normal(kq, (h, m, d)),
            jax.random.normal(kk, (b, h, n, d)),
            jax.random.normal(kv, (b, h, n, d)))


class TestPolicyStack:
    def test_default_policy(self):
        pol = current_policy()
        assert pol.backends == ("auto",) and not pol.requires_grad

    def test_nested_override_and_restore(self):
        base = current_policy()
        with mixer_policy(backends=("sdpa",)) as outer:
            assert current_policy() is outer
            assert current_policy().backends == ("sdpa",)
            with mixer_policy(requires_grad=True) as inner:
                # inner layers on top of outer, not on the base
                assert current_policy() is inner
                assert inner.backends == ("sdpa",) and inner.requires_grad
            assert current_policy() is outer and not outer.requires_grad
        assert current_policy() is base

    def test_restore_on_exception(self):
        base = current_policy()
        with pytest.raises(RuntimeError):
            with mixer_policy(backends=("materialized",)):
                raise RuntimeError("boom")
        assert current_policy() is base

    def test_explicit_policy_plus_overrides(self):
        pol = MixerPolicy(backends=("sdpa", "materialized"))
        with mixer_policy(pol, requires_grad=True) as active:
            assert active.backends == ("sdpa", "materialized")
            assert active.requires_grad

    def test_ambient_policy_drives_flare_mixer(self):
        q, k, v = _qkv()
        with mixer_policy(backends=("materialized",)):
            y = flare_mixer(q, k, v)
        ref = flare_mixer(q, k, v, policy=MixerPolicy(backends=("materialized",)))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-6)


class TestHashability:
    def test_hash_and_dict_key(self):
        a = MixerPolicy(backends=("sdpa",), requires_grad=True)
        b = MixerPolicy(backends=("sdpa",), requires_grad=True)
        assert a == b and hash(a) == hash(b)
        assert {a: 1}[b] == 1

    def test_string_backends_normalized(self):
        assert MixerPolicy(backends="sdpa") == MixerPolicy(backends=("sdpa",))
        assert MixerPolicy(seq_axes="data").seq_axes == ("data",)

    def test_usable_as_jit_static_arg(self):
        calls = []

        @functools.partial(jax.jit, static_argnums=1)
        def f(x, pol: MixerPolicy):
            calls.append(pol)
            return x * (2.0 if pol.requires_grad else 1.0)

        x = jnp.ones(3)
        p1 = MixerPolicy(requires_grad=True)
        np.testing.assert_allclose(np.asarray(f(x, p1)), 2.0 * np.ones(3))
        # equal policy -> cache hit, no retrace
        n = len(calls)
        f(x, MixerPolicy(requires_grad=True))
        assert len(calls) == n
        # different policy -> retrace with the new static value
        np.testing.assert_allclose(np.asarray(f(x, MixerPolicy())), np.ones(3))

    def test_pytree_static_registration(self):
        # a policy inside a pytree is aux data (no leaves), so it can ride
        # through jax.tree.map and jit closures untouched
        tree = {"pol": MixerPolicy(backends=("sdpa",)), "x": jnp.ones(2)}
        leaves = jax.tree.leaves(tree)
        assert len(leaves) == 1  # only x — the policy is static structure


class TestLegacyAliases:
    def test_string_impl_warns_and_resolves(self):
        with pytest.deprecated_call():
            plan = resolve_policy("sdpa", SHAPE, jnp.float32)
        assert plan.backend == "sdpa"

    def test_legacy_tuple_warns_and_resolves(self):
        mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1), ("s", "l"))
        with pytest.deprecated_call():
            plan = resolve_policy(("sp", mesh, "s"), SHAPE, jnp.float32)
        assert plan.backend == "seqparallel" and plan.params["seq_axes"] == "s"

    def test_flare_mixer_impl_kwarg_warns(self):
        q, k, v = _qkv()
        with pytest.deprecated_call():
            y = flare_mixer(q, k, v, impl="sdpa")
        assert y.shape == v.shape

    def test_get_model_flare_impl_kwarg(self):
        from repro.config import AttnConfig, ModelConfig
        from repro.models.api import get_model

        cfg = ModelConfig(name="t", family="pde", num_layers=1, d_model=32,
                          d_ff=32, vocab=0, attn=AttnConfig(kind="none"),
                          flare_heads=4, flare_latents=8)
        with pytest.deprecated_call():
            model = get_model(cfg, flare_impl="sdpa")
        assert model.plans["infer"].backend == "sdpa"


class TestResolution:
    def test_plan_passthrough(self):
        plan = MixerPlan("sdpa")
        assert resolve_policy(plan, SHAPE, jnp.float32) is plan

    def test_preference_order_falls_through(self):
        # causal_pallas fails the bidirectional contract; sdpa picks it up
        pol = MixerPolicy(backends=("causal_pallas", "sdpa"))
        assert resolve_policy(pol, SHAPE, jnp.float32).backend == "sdpa"

    def test_single_name_contract_error_is_hard(self):
        with pytest.raises(ValueError, match="not causal"):
            resolve_policy(MixerPolicy(backends=("sdpa",)), SHAPE, jnp.float32,
                           causal=True)

    def test_exhausted_preference_reports_reasons(self):
        pol = MixerPolicy(backends=("pallas", "causal_pallas"),
                          requires_grad=True)
        with pytest.raises(ValueError, match="preference order"):
            resolve_policy(pol, SHAPE, jnp.float32)

    def test_policy_dtype_overrides_data_dtype(self):
        pol = MixerPolicy(dtype="bfloat16")
        assert pol.dtype == "bfloat16"
        plan = resolve_policy(pol, SHAPE, jnp.float32)
        assert plan.backend  # resolves under the override without error

    def test_causal_chunk_size_override(self):
        pol = MixerPolicy(chunk_size=32)
        plan = resolve_policy(pol, SHAPE, jnp.float32, causal=True)
        assert plan.params["chunk_size"] == 32

    def test_sharded_hints_resolve_via_mesh(self):
        mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                                 ("data", "model"))
        pol = MixerPolicy(seq_axes=("data", "model"))
        plan = resolve_policy(pol, SHAPE, jnp.float32, mesh=mesh)
        assert plan.backend == "seqparallel"
        pol2d = MixerPolicy(seq_axes=("data",), lat_axes=("model",))
        plan2d = resolve_policy(pol2d, SHAPE, jnp.float32, mesh=mesh)
        assert plan2d.backend == "seqlat"
        # a matching explicit name is fine; a conflicting one is an error,
        # never a silent override
        named = MixerPolicy(backends=("seqparallel",), seq_axes=("data", "model"))
        assert resolve_policy(named, SHAPE, jnp.float32,
                              mesh=mesh).backend == "seqparallel"
        clash = MixerPolicy(backends=("sdpa",), seq_axes=("data", "model"))
        with pytest.raises(ValueError, match="axis hints"):
            resolve_policy(clash, SHAPE, jnp.float32, mesh=mesh)

    def test_describe_distinguishes_non_defaults(self):
        assert MixerPolicy().describe() == "MixerPolicy(auto)"
        assert "autotune=False" in MixerPolicy(autotune=False).describe()
        assert "requires_grad=True" in MixerPolicy(requires_grad=True).describe()
        assert MixerPolicy(autotune=False).describe() != MixerPolicy().describe()

    def test_run_plan_matches_reference(self):
        q, k, v = _qkv()
        plan = resolve_policy(MixerPolicy(backends=("materialized",)),
                              MixerShape.from_qkv(q, k), k.dtype)
        y = run_plan(plan, q, k, v)
        ref = flare_mixer(q, k, v, policy=MixerPolicy(backends=("sdpa",)))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


class TestRequiresGradContract:
    """Regression: a requires_grad=True policy can NEVER resolve the
    forward-only kernels, on either contract, on any device kind."""

    @pytest.mark.parametrize("name,causal", [("pallas", False),
                                             ("causal_pallas", True)])
    def test_named_forward_only_backend_is_an_error(self, name, causal):
        pol = MixerPolicy(backends=(name,), requires_grad=True)
        with pytest.raises(ValueError, match="forward-only"):
            resolve_policy(pol, SHAPE, jnp.float32, causal=causal)

    @pytest.mark.parametrize("causal", [False, True], ids=["bidi", "causal"])
    def test_auto_never_lands_forward_only(self, causal):
        pol = MixerPolicy(requires_grad=True)
        for dev in ("cpu", "gpu", "tpu"):
            cands = [b for b in dispatch.backends(causal=causal, sharded=False)
                     if dispatch.eligible(b, causal=causal, dtype=jnp.float32,
                                          device=dev, grad=True)]
            assert cands, dev
            assert all(b.caps.grads for b in cands)
            assert "pallas" not in {b.name for b in cands}
            assert "causal_pallas" not in {b.name for b in cands}
        # and the actual resolution on this device
        plan = resolve_policy(pol, SHAPE, jnp.float32, causal=causal)
        assert dispatch.get_backend(plan.backend).caps.grads

    def test_preference_order_skips_forward_only_under_grad(self):
        pol = MixerPolicy(backends=("pallas", "sdpa"), requires_grad=True)
        assert resolve_policy(pol, SHAPE, jnp.float32).backend == "sdpa"
        polc = MixerPolicy(backends=("causal_pallas", "causal_stream"),
                           requires_grad=True)
        assert resolve_policy(polc, SHAPE, jnp.float32,
                              causal=True).backend == "causal_stream"

    def test_ensure_plan_rechecks_grad_contract(self):
        plan = MixerPlan("pallas", {"block_m": 128, "block_n": 512})
        with mixer_policy(requires_grad=True):
            with pytest.raises(ValueError, match="forward-only"):
                ensure_plan(plan, SHAPE, jnp.float32)
        # outside the training scope the same plan is fine
        assert ensure_plan(plan, SHAPE, jnp.float32) is plan

    def test_loss_paths_use_grad_capable_plans(self):
        """get_model resolves the loss plan with requires_grad=True even if
        the policy did not ask for it."""
        from repro.config import AttnConfig, ModelConfig
        from repro.models.api import get_model

        cfg = ModelConfig(name="t", family="pde", num_layers=1, d_model=32,
                          d_ff=32, vocab=0, attn=AttnConfig(kind="none"),
                          flare_heads=4, flare_latents=8)
        model = get_model(cfg, policy=MixerPolicy())
        assert dispatch.get_backend(model.plans["train"].backend).caps.grads

        lm = ModelConfig(name="lm", family="flare_lm", num_layers=1,
                         d_model=32, d_ff=64, vocab=64,
                         attn=AttnConfig(kind="flare_stream", num_heads=4,
                                         head_dim=8, flare_latents=4,
                                         flare_chunk=8))
        model = get_model(lm, policy=MixerPolicy(), seq_len_hint=32)
        train = model.plans["train"]
        assert dispatch.get_backend(train.backend).caps.grads
        assert dispatch.get_backend(train.backend).caps.causal
        assert train.params["chunk_size"] == 8  # cfg chunk baked at build


class TestBuildTimeResolution:
    def test_model_plans_are_exposed_and_run(self):
        from repro.config import AttnConfig, ModelConfig
        from repro.models.api import get_model

        cfg = ModelConfig(name="lm", family="flare_lm", num_layers=1,
                          d_model=32, d_ff=64, vocab=64,
                          attn=AttnConfig(kind="flare_stream", num_heads=4,
                                          head_dim=8, flare_latents=4,
                                          flare_chunk=8), remat="none")
        model = get_model(cfg, seq_len_hint=16)
        assert set(model.plans) == {"train", "infer"}
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
                 "labels": jnp.zeros((2, 16), jnp.int32)}
        loss = model.loss(params, batch)
        assert jnp.isfinite(loss)
        g = jax.grad(lambda p: model.loss(p, batch))(params)
        assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(g))

    def test_inference_only_policy_builds_and_serves(self):
        """A policy naming only a forward-only backend must still build a
        servable model; only model.loss errors (with the resolve reason)."""
        from repro.config import AttnConfig, ModelConfig
        from repro.models.api import get_model

        cfg = ModelConfig(name="lm", family="flare_lm", num_layers=1,
                          d_model=32, d_ff=64, vocab=64,
                          attn=AttnConfig(kind="flare_stream", num_heads=4,
                                          head_dim=8, flare_latents=4,
                                          flare_chunk=8), remat="none")
        model = get_model(cfg, policy=MixerPolicy(backends=("causal_pallas",)),
                          seq_len_hint=16)
        assert model.plans["infer"].backend == "causal_pallas"
        assert "train" not in model.plans
        params = model.init(jax.random.PRNGKey(0))
        logits, _ = model.forward(params, {"tokens": jnp.zeros((1, 16), jnp.int32)})
        assert jnp.all(jnp.isfinite(logits))
        batch = {"tokens": jnp.zeros((1, 16), jnp.int32),
                 "labels": jnp.zeros((1, 16), jnp.int32)}
        with pytest.raises(ValueError, match="inference-only"):
            model.loss(params, batch)

    def test_serve_engine_reports_build_plan(self):
        from repro.config import AttnConfig, ModelConfig
        from repro.models.api import get_model
        from repro.serve.engine import ServeEngine

        cfg = ModelConfig(name="lm", family="flare_lm", num_layers=1,
                          d_model=32, d_ff=64, vocab=64,
                          attn=AttnConfig(kind="flare_stream", num_heads=4,
                                          head_dim=8, flare_latents=4,
                                          flare_chunk=8), remat="none")
        model = get_model(cfg, seq_len_hint=32)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, capacity=32)
        assert engine.stats["mixer_backend"] == model.plans["infer"].describe()


class TestAutotuneVersionedKeys:
    def test_cache_key_carries_runtime_version(self):
        from repro.backends import autotune

        key = autotune.cache_key(SHAPE, jnp.float32, "cpu")
        legacy = autotune.legacy_cache_key(SHAPE, jnp.float32, "cpu")
        assert key.startswith(legacy) and autotune.runtime_version() in key
        assert "jax" in autotune.runtime_version()

    def test_legacy_unversioned_entry_still_hits(self, tmp_path, monkeypatch):
        import json

        from repro.backends import autotune

        path = tmp_path / "tiles.json"
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        autotune._MEM_CACHE.clear()
        legacy_key = autotune.legacy_cache_key(SHAPE, jnp.float32, "cpu")
        path.write_text(json.dumps({legacy_key: {"block_m": 16, "block_n": 384}}))
        got = autotune.best_tiles(SHAPE, jnp.float32, "cpu")
        assert got == {"block_m": 16, "block_n": 384}

    def test_new_measurements_store_versioned(self, tmp_path, monkeypatch):
        import json

        from repro.backends import autotune

        path = tmp_path / "tiles.json"
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        autotune._MEM_CACHE.clear()
        autotune.measure_tiles(SHAPE, jnp.float32, "cpu",
                               lambda t: 0.001 if t["block_n"] == 256 else 0.002)
        data = json.loads(path.read_text())
        assert list(data) == [autotune.cache_key(SHAPE, jnp.float32, "cpu")]
        # versioned winner is read back after a cold start
        autotune._MEM_CACHE.clear()
        assert autotune.best_tiles(SHAPE, jnp.float32, "cpu")["block_n"] == 256

    def test_versioned_entry_wins_over_legacy(self, tmp_path, monkeypatch):
        import json

        from repro.backends import autotune

        path = tmp_path / "tiles.json"
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        autotune._MEM_CACHE.clear()
        path.write_text(json.dumps({
            autotune.legacy_cache_key(SHAPE, jnp.float32, "cpu"):
                {"block_m": 8, "block_n": 128},
            autotune.cache_key(SHAPE, jnp.float32, "cpu"):
                {"block_m": 32, "block_n": 512},
        }))
        assert autotune.best_tiles(SHAPE, jnp.float32, "cpu") == {
            "block_m": 32, "block_n": 512}

    def test_policy_autotune_optin_scopes_enablement(self, monkeypatch):
        from repro.backends import autotune

        monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
        assert not autotune.autotune_enabled()
        with autotune.forced(True):
            assert autotune.autotune_enabled()
            with autotune.forced(False):
                assert not autotune.autotune_enabled()
            assert autotune.autotune_enabled()
        assert not autotune.autotune_enabled()
