"""Checkpoint manager: roundtrip, atomicity, corruption, keep-k, elastic."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


@pytest.fixture
def tree():
    return {
        "layers": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,), jnp.bfloat16)},
        "step_arr": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(10, tree, blocking=True)
    restored = cm.restore(10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_then_wait(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(5, tree, blocking=False)
    cm.wait()
    assert cm.latest_step() == 5


def test_keep_k_gc(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    assert cm.all_steps() == [3, 4]


def test_corruption_detected(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(1, tree, blocking=True)
    meta_path = os.path.join(str(tmp_path), "step_1", "meta.json")
    meta = json.load(open(meta_path))
    next(iter(meta["leaves"].values()))["crc32"] ^= 0xDEADBEEF
    json.dump(meta, open(meta_path, "w"))
    with pytest.raises(IOError, match="corruption"):
        cm.restore(1, tree)


def test_latest_ignores_incomplete(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, tree, blocking=True)
    # simulate a crash mid-write: a .tmp dir and a step dir without meta
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    os.makedirs(os.path.join(str(tmp_path), "step_8"))
    assert cm.latest_step() == 1


def test_elastic_restore_across_shardings(tmp_path, tree):
    """Save unsharded, restore with explicit single-device shardings (the
    mesh-shape-agnostic path used at pod scale)."""
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(3, tree, blocking=True)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    restored = cm.restore(3, tree, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
