"""The trip-count-aware HLO analyzer vs known ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import V5E, roofline_terms


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=1e-6)


def test_scan_trip_count_scaling():
    """A 10-iteration scan must report ~10x the flops of its body — the
    exact failure mode of raw cost_analysis (DESIGN.md §7)."""
    w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        return jax.lax.scan(body, x, w)[0].sum()

    c = _compile(f, w, x)
    r = analyze_hlo(c.as_text())
    body_flops = 2 * 8 * 64 * 64
    assert r["flops"] == pytest.approx(10 * body_flops, rel=0.05)
    # raw cost_analysis undercounts:
    ca = c.cost_analysis()
    d = ca if isinstance(ca, dict) else ca[0]
    assert d["flops"] < 2 * body_flops


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((3, 4, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 16), jnp.float32)

    def f(w, x):
        def outer(x, wo):
            def inner(x, wl):
                return jnp.tanh(x @ wl), None
            return jax.lax.scan(inner, x, wo)[0], None
        return jax.lax.scan(outer, x, w)[0].sum()

    c = _compile(f, w, x)
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 2 * 16 * 16, rel=0.05)


def test_memory_traffic_lower_bound():
    """Elementwise op: traffic >= in + out bytes."""
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda x: jnp.tanh(x) * 2.0, x)
    r = analyze_hlo(c.as_text())
    assert r["mem_bytes"] >= 2 * 1024 * 1024 * 4 * 0.99


def test_roofline_terms_math():
    analysis = {"flops": V5E.peak_flops, "mem_bytes": 2 * V5E.hbm_bw,
                "collective_bytes": 0.5 * V5E.ici_bw}
    t = roofline_terms(analysis, model_flops_per_device=V5E.peak_flops / 2)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] == "memory"
    assert t["useful_compute_ratio"] == pytest.approx(0.5)
    assert t["bound_overlap_s"] == pytest.approx(2.0)
    assert t["mfu_overlap_bound"] == pytest.approx(0.25)


def test_collectives_counted_inside_scan():
    """Collective bytes inside a scanned body scale with the trip count."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, sys
sys.path.insert(0, "src")
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.analysis.hlo import analyze_hlo
from repro.distributed.compat import make_mesh
mesh = make_mesh((4,), ("d",))
w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
x = jax.ShapeDtypeStruct((4, 128), jnp.float32)
def f(w, x):
    def body(x, wl):
        return jnp.tanh(x @ wl), None
    return jax.lax.scan(body, x, w)[0].sum()
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None, "d")),
                             NamedSharding(mesh, P()))).lower(w, x).compile()
r = analyze_hlo(c.as_text())
assert r["collective_bytes"] > 0, "expected collectives"
counts = r["collective_counts"]
assert sum(counts.values()) >= 8, counts  # one+ per scan iteration
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
