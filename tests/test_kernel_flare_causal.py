"""Pallas causal-FLARE chunk kernel vs the jnp factored/exact references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flare_stream import flare_causal, flare_causal_ref
from repro.kernels.ops import flare_causal_fused

KEY = jax.random.PRNGKey(11)


def _qkv(b, h, n, m, d, scale=0.5):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (h, m, d)) * scale
    k = jax.random.normal(ks[1], (b, h, n, d)) * scale
    v = jax.random.normal(ks[2], (b, h, n, d))
    return q, k, v


@pytest.mark.parametrize("b,h,n,m,d,tile", [
    (1, 2, 64, 16, 8, 16),
    (2, 1, 128, 32, 16, 32),
    (1, 1, 96, 8, 8, 32),   # n not a multiple of the default tile
])
def test_kernel_matches_oracle(b, h, n, m, d, tile):
    q, k, v = _qkv(b, h, n, m, d)
    y_k = flare_causal_fused(q, k, v, tile=tile)
    y_ref = flare_causal_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=2e-5)


def test_kernel_matches_factored_jnp_path():
    q, k, v = _qkv(1, 2, 64, 16, 8, scale=1.5)
    y_k = flare_causal_fused(q, k, v, tile=16)
    y_j = flare_causal(q, k, v, chunk_size=16, impl="factored")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), atol=2e-5)


def test_kernel_tile_invariance():
    q, k, v = _qkv(1, 1, 64, 8, 8)
    y16 = flare_causal_fused(q, k, v, tile=16)
    y64 = flare_causal_fused(q, k, v, tile=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=2e-5)


def test_kernel_bf16():
    q, k, v = _qkv(1, 2, 64, 16, 8)
    y_k = flare_causal_fused(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), tile=16)
    y_ref = flare_causal_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_ref),
                               atol=3e-2, rtol=3e-2)
