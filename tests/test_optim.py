"""Optimizer + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_update, clip_by_global_norm, global_norm, init_adamw
from repro.optim.schedule import onecycle_schedule


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = init_adamw(params)
    loss_fn = lambda p: jnp.sum(jnp.square(p["w"] - jnp.array([1.0, 2.0, 3.0])))
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(params, g, opt, lr=0.05)
    np.testing.assert_allclose(params["w"], [1.0, 2.0, 3.0], atol=0.05)


def test_weight_decay_shrinks():
    params = {"w": jnp.array([10.0])}
    opt = init_adamw(params)
    zeros = {"w": jnp.zeros(1)}
    p2, _, _ = adamw_update(params, zeros, opt, lr=0.1, weight_decay=0.1)
    assert float(p2["w"][0]) < 10.0


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - 20.0) < 1e-4


def test_moments_are_fp32():
    params = {"w": jnp.zeros((3,), jnp.bfloat16)}
    opt = init_adamw(params)
    assert opt.m["w"].dtype == jnp.float32
    assert opt.v["w"].dtype == jnp.float32


def test_onecycle_shape():
    total, peak = 100, 1e-3
    lrs = [float(onecycle_schedule(s, total_steps=total, peak_lr=peak, warmup_frac=0.1))
           for s in range(total + 1)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - peak) < 1e-9
    assert np.argmax(lrs) == 10  # warmup ends at 10%
    assert lrs[-1] < peak / 100  # decayed
    # monotonic up then down
    assert all(a <= b + 1e-12 for a, b in zip(lrs[:10], lrs[1:11]))
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:-1], lrs[11:]))


def test_update_is_sharding_free_pure():
    """adamw_update must preserve tree structure and dtypes."""
    params = {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros((4,), jnp.bfloat16)}}
    opt = init_adamw(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, opt2, _ = adamw_update(params, g, opt, lr=0.1)
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    assert p2["b"]["c"].dtype == jnp.bfloat16
    assert int(opt2.step) == 1
