#!/usr/bin/env bash
# CI entry point: fast test tier + interpret-mode kernel-parity smoke.
#
# Runs on CPU — every Pallas kernel executes in interpret mode, so kernel
# regressions (layout, masking, VJP) are caught without a TPU. The slow
# tier (subprocess device farms, end-to-end trains, the broad smoke matrix)
# is excluded; run `python -m pytest -x -q` before shipping (ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== policy-resolution smoke (backend x policy eligibility) =="
# every registered backend against the four canonical policies; fails if any
# canonical policy (bidi/causal x infer/train) has no eligible backend.
# (-W: runpy warns that repro.core already imported dispatch — benign; the
# __main__ stub delegates to the canonical module instance)
dispatch_list="$(python -W "ignore::RuntimeWarning" -m repro.core.dispatch --list)"
echo "$dispatch_list"
# the paged serve pool's kernel must stay policy-addressable (DESIGN.md §4)
echo "$dispatch_list" | grep -q "^paged " \
    || { echo "ERROR: 'paged' backend missing from the registry"; exit 1; }
# ...and auto-resolvable for decode-shaped pools (latents=1 scores above the
# dense backends) while staying out of dense call sites — the fused decode
# step's routing contract (DESIGN.md §4 "Fused decode step")
python - <<'PY'
import jax.numpy as jnp
from repro.core.dispatch import MixerShape
from repro.core.policy import MixerPolicy, resolve_policy

decode = MixerShape(batch=4, heads=2, tokens=64, latents=1, head_dim=8)
plan = resolve_policy(MixerPolicy(), decode, jnp.dtype("bfloat16"), causal=False)
assert plan.backend == "paged", f"decode-shaped auto pick: {plan.backend}"
dense = MixerShape(batch=4, heads=2, tokens=64, latents=8, head_dim=8)
plan = resolve_policy(MixerPolicy(), dense, jnp.dtype("bfloat16"), causal=False)
assert plan.backend != "paged", f"dense M>1 site leaked to paged: {plan.backend}"
print(f"paged routing OK (decode->paged, dense->{plan.backend})")
PY

echo "== mesh-parallel eligibility smoke (DESIGN.md §15) =="
# both sharded backends must be registered with the mesh-eligibility columns,
# and the strict symmetry must hold: sharded backends never auto-resolve
# without a mesh, and naming one without a mesh is a hard error
echo "$dispatch_list" | grep -q "^packed_shard " \
    || { echo "ERROR: 'packed_shard' backend missing from the registry"; exit 1; }
echo "$dispatch_list" | grep -q "^paged_shard " \
    || { echo "ERROR: 'paged_shard' backend missing from the registry"; exit 1; }
echo "$dispatch_list" | grep -q "with-mesh" \
    || { echo "ERROR: dispatch --list lost the mesh-eligibility columns"; exit 1; }
python - <<'PY'
import jax.numpy as jnp
from repro.core.dispatch import MixerShape, get_backend, resolve

shape = MixerShape(batch=4, heads=4, tokens=64, latents=8, head_dim=8)
for causal in (False, True):
    for grad in (False, True):
        _, plan = resolve("auto", shape=shape, dtype=jnp.float32,
                          causal=causal, grad=grad)
        assert not get_backend(plan.backend).caps.sharded, \
            f"auto without a mesh picked sharded backend {plan.backend}"
for name in ("packed_shard", "paged_shard"):
    try:
        resolve(name, shape=shape, dtype=jnp.float32, causal=False)
    except ValueError:
        pass
    else:
        raise SystemExit(f"{name} resolved without a mesh")
print("mesh eligibility OK (sharded backends strictly mesh-gated)")
PY

echo "== flarecheck (static analysis, DESIGN.md §14) =="
# rule catalog must be non-empty (a registration regression would silently
# turn the gate into a no-op), then the gate itself: any finding not in the
# committed baseline fails the build before a single test runs
rules="$(python -m repro.analysis.lint --list-rules)"
[ -n "$rules" ] || { echo "ERROR: flarecheck rule catalog is empty"; exit 1; }
echo "$rules"
python -m repro.analysis.lint src tests --baseline .flarecheck.json

echo "== fast tier (pytest -m 'not slow', allocator sanitizer on) =="
REPRO_SANITIZE=1 python -m pytest -x -q -m "not slow"

echo "== interpret-mode kernel-parity smoke =="
# quick standalone guard: the fused kernels (packed + classic) against the
# jnp oracles, exactly what a kernel regression would break first
python -m pytest -x -q tests/test_kernels.py tests/test_packed.py \
    -k "sweep or oracles or matches"

echo "== continuous-batching serve smoke =="
# slot-pool engine end-to-end on the FLARE-LM smoke config (DESIGN.md §4)
python -m repro.launch.serve --arch flare_lm --smoke --requests 4 --max-new 8
# one-row serving benchmark through the harness contract (includes a paged
# row: admitted-slot + HBM-bytes columns at a fixed byte budget)
REPRO_BENCH_TAG=none REPRO_BENCH_SERVE_SMOKE=1 python -m benchmarks.run serve

echo "== paged-pool smoke (DESIGN.md §4 'Paged pool') =="
# a pool small enough (48 tokens = 6 blocks, vs ~4 pages/request worst
# case) to force page-granular admission backpressure, while max-new
# pushes every request across at least one block boundary (page appends)
python -m repro.launch.serve --arch qwen2_1_5b --smoke --requests 6 \
    --max-new 12 --capacity 32 --slots 4 --pool-tokens 48 --block-size 8 \
    --kv-quant int8 --coalesce

echo "== fused decode-step smoke (DESIGN.md §4 'Fused decode step') =="
# kernel-backed paged decode (forced, not auto) with warmup: the steady-state
# loop must add ZERO decode-step compiles after warmup, and the fused
# sampler must keep per-step host syncs at 0 (both enforced by the launcher)
out="$(python -m repro.launch.serve --arch qwen2_1_5b --smoke --requests 6 \
    --max-new 12 --capacity 32 --slots 4 --pool-tokens 96 --block-size 8 \
    --decode-backend paged --warmup --max-decode-compiles 0)"
echo "$out"
echo "$out" | grep -q "decode backend: paged(" \
    || { echo "ERROR: serve smoke did not route through the paged kernel"; exit 1; }
echo "$out" | grep -q "host syncs/step: 0.0" \
    || { echo "ERROR: fused decode step is syncing logits to the host"; exit 1; }

echo "== observability smoke (DESIGN.md §16) =="
# the fused-decode config again, now with span tracing + a metrics dump:
# tracing is host-side relabeling of stamps the engine already takes, so
# the zero-host-sync invariant must survive it — and the exported trace
# must be schema-valid Chrome trace-event JSON covering every lifecycle
# phase with monotonic timestamps
obs_dir="$(mktemp -d)"
trap 'rm -rf "$obs_dir"' EXIT
obs_out="$(python -m repro.launch.serve --arch qwen2_1_5b --smoke --requests 6 \
    --max-new 12 --capacity 32 --slots 4 --pool-tokens 96 --block-size 8 \
    --decode-backend paged --warmup --max-decode-compiles 0 \
    --trace-out "$obs_dir/trace.json" --metrics-out "$obs_dir/metrics.json")"
echo "$obs_out" | tail -n 6
echo "$obs_out" | grep -q "host syncs/step: 0.0" \
    || { echo "ERROR: tracing broke the fused decode step's zero-sync invariant"; exit 1; }
OBS_DIR="$obs_dir" python - <<'PY'
import json, os

d = os.environ["OBS_DIR"]
doc = json.load(open(os.path.join(d, "trace.json")))
evs = doc["traceEvents"]
assert isinstance(evs, list) and evs, "empty traceEvents"
data = [e for e in evs if e.get("ph") != "M"]
for e in data:
    assert {"name", "ph", "ts", "pid", "tid"} <= set(e), f"malformed event {e}"
    assert e["ph"] in ("X", "i"), f"unexpected phase {e['ph']!r}"
ts = [e["ts"] for e in data]
assert ts == sorted(ts) and ts[0] == 0.0, "trace ts not monotonic/rebased"
names = {e["name"] for e in data}
from repro.obs.trace import PHASES
missing = [p for p in PHASES if p not in names]
assert not missing, f"lifecycle phases missing from trace: {missing}"
metrics = json.load(open(os.path.join(d, "metrics.json")))["metrics"]
for series in ("sched.admitted", "engine.tokens_out", "engine.decode_step_s"):
    assert series in metrics, f"metrics dump missing {series}"
assert metrics["sched.admitted"] == 6.0, metrics["sched.admitted"]
print(f"obs smoke OK ({len(data)} spans, {len(metrics)} metric series, "
      "all lifecycle phases present)")
PY

echo "== prefix-cache smoke (DESIGN.md §4 'Prefix cache') =="
# two waves sharing a 40-token template on a tiny pool: the cached+pinned
# run must (a) report a nonzero hit rate, (b) exercise at least one
# copy-on-write (request 0 is the exact template and the pin probe has
# already registered it), and (c) emit BIT-identical greedy outputs to a
# cold-cache run of the same seeded workload
warm="$(python -m repro.launch.serve --arch qwen2_1_5b --smoke --requests 6 \
    --prompt-len 40 --max-new 6 --capacity 64 --slots 4 --pool-tokens 192 \
    --block-size 8 --share-prefix 1 --prefix-cache --pin-prompt)"
echo "$warm" | grep "prefix cache:"
echo "$warm" | grep -q "prefix cache: enabled=True hit_rate=0\.[1-9]" \
    || { echo "ERROR: prefix cache reported a zero hit rate"; exit 1; }
echo "$warm" | grep "prefix cache:" | grep -q "cow_copies=0" \
    && { echo "ERROR: expected at least one copy-on-write"; exit 1; }
cold="$(python -m repro.launch.serve --arch qwen2_1_5b --smoke --requests 6 \
    --prompt-len 40 --max-new 6 --capacity 64 --slots 4 --pool-tokens 192 \
    --block-size 8 --share-prefix 1)"
diff <(echo "$warm" | grep '^req ') <(echo "$cold" | grep '^req ') \
    || { echo "ERROR: prefix-cache outputs diverge from the cold run"; exit 1; }
echo "prefix-cache smoke OK (bit-identical to cold run)"

echo "CI OK"
