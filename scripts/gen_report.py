"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run artifacts.

    PYTHONPATH=src python scripts/gen_report.py [--dir experiments/artifacts]
Writes experiments/dryrun_table.md and experiments/roofline_table.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(art_dir):
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        recs.append(json.load(open(p)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3,
             "pde_40k": 4, "pde_1m": 5}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return recs


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | devices | compile (s) | peak GiB/dev | HLO GFLOPs/dev | HLO GB/dev | coll. GB/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            reason = (r.get("reason") or r.get("error") or "")[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **{r['status']}** "
                         f"| | | | | | | {reason} |")
            continue
        h = r["hlo_analysis"]
        mem = r["memory_analysis"]
        gib = mem.get("peak_bytes_per_device_est", 0) / 2**30
        colls = h.get("collectives", {})
        top = ", ".join(f"{k}:{v / 1e9:.1f}GB" for k, v in
                        sorted(colls.items(), key=lambda kv: -kv[1])[:2])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['devices']} "
            f"| {r['compile_s']} | {gib:.1f} | {h['flops'] / 1e9:.0f} "
            f"| {h['mem_bytes'] / 1e9:.0f} | {h['collective_bytes'] / 1e9:.1f} | {top} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch | shape | T_compute (s) | T_memory (s) | T_collective (s) | dominant | MODEL_FLOPS/dev | useful ratio | MFU bound | one-line: what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        ro = r["roofline"]
        note = _note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
            f"| {ro['collective_s']:.3f} | **{ro['dominant']}** "
            f"| {ro.get('model_flops_per_device', 0):.2e} "
            f"| {ro.get('useful_compute_ratio', 0):.3f} "
            f"| {ro.get('mfu_overlap_bound', 0):.4f} | {note} |")
    return "\n".join(lines)


def _note(r):
    ro = r["roofline"]
    dom = ro["dominant"]
    arch, shape = r["arch"], r["shape"]
    if dom == "collective":
        return "replace GSPMD activation reshards with the O(M*C) latent-stat psum (shard_map SP-FLARE)"
    if shape.startswith("decode") or shape == "long_500k":
        return "decode is weight/cache-streaming bound: shard params over model only; batch more requests per step"
    if arch == "rwkv6_3b":
        return "factor the intra-chunk [T,T,D] decay-ratio tensor into clamped [T,D]x[D,T] matmuls"
    if arch.startswith("flare_lm"):
        return "shrink flare_chunk + pin head sharding so the [B,H,M,T,D] scan buffer stays per-device-small"
    if arch == "mixtral_8x7b":
        return "reshard the [G,S,E,cap] dispatch tensors (EP-aligned) to kill the all-gather storm"
    return "fuse softmax/score traffic into the attention kernel (Pallas flash path on TPU); raise microbatch arithmetic intensity"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/artifacts")
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()
    recs = load(args.dir)
    os.makedirs("experiments", exist_ok=True)
    with open(f"experiments/dryrun_table{args.suffix}.md", "w") as f:
        f.write(dryrun_table(recs) + "\n")
    with open(f"experiments/roofline_table{args.suffix}.md", "w") as f:
        f.write("### single-pod (16x16 = 256 chips)\n\n")
        f.write(roofline_table(recs, "single") + "\n\n")
        f.write("### multi-pod (2x16x16 = 512 chips)\n\n")
        f.write(roofline_table(recs, "multi") + "\n")
    n_ok = sum(r["status"] == "ok" for r in recs)
    print(f"rendered {n_ok} ok cells -> experiments/*_table{args.suffix}.md")


if __name__ == "__main__":
    main()
